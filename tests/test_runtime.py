"""Runtime substrate: optimizer, checkpoint, serving engine, scheduler,
data pipeline, shardings, HLO collective parser."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt
from repro.configs import get_config
from repro.data import pipeline as dp
from repro.launch import shardings as sh
from repro.launch.mesh import make_host_mesh
from repro.models import transformer as T
from repro.models.modules import ExecContext
from repro.serving.engine import ServingEngine
from repro.serving.scheduler import Request, Scheduler
from repro.training.optim import AdamWConfig, adamw_init, adamw_update


def test_adamw_minimizes_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0])}
    opt = adamw_init(params)
    cfg = AdamWConfig(lr=0.2, warmup_steps=1, total_steps=200,
                      weight_decay=0.0)
    for _ in range(150):
        grads = {"w": 2 * params["w"]}
        params, opt = adamw_update(grads, opt, params, cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.1


def test_ckpt_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.int32)}}
    path = str(tmp_path / "x.ckpt")
    ckpt.save(path, tree)
    out = ckpt.restore(path, tree)
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_ckpt_bf16_roundtrip(tmp_path):
    tree = {"w": jnp.ones((3, 3), jnp.bfloat16) * 1.5}
    path = str(tmp_path / "b.ckpt")
    ckpt.save(path, tree)
    out = ckpt.restore(path, tree)
    assert out["w"].dtype == jnp.bfloat16


def test_engine_generate_and_policy_swap():
    cfg = get_config("qwen-sim-1.5b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    eng = ServingEngine(params, cfg, max_ctx=64)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
    r16 = eng.generate({"tokens": toks}, max_new=4)
    assert r16.new_tokens.shape == (2, 4)
    assert r16.tokens.shape == (2, 20)
    # greedy determinism
    r16b = eng.generate({"tokens": toks}, max_new=4)
    np.testing.assert_array_equal(np.asarray(r16.new_tokens),
                                  np.asarray(r16b.new_tokens))
    # swap to an FP4 policy: still runs, latency model reflects fewer bits
    eng.set_policy({}, default_bits=4, avg_bits=4.0)
    r4 = eng.generate({"tokens": toks}, max_new=4)
    assert r4.new_tokens.shape == (2, 4)
    assert r4.latency_s < r16.latency_s


def test_scheduler_serves_all():
    cfg = get_config("qwen-sim-1.5b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    eng = ServingEngine(params, cfg, max_ctx=64)
    sched = Scheduler(eng, batch_slots=4)
    rng = np.random.default_rng(0)
    for rid in range(10):
        sched.submit(Request(rid=rid,
                             prompt=rng.integers(0, cfg.vocab, 12).astype(np.int32),
                             max_new=4, deadline_s=10.0))
    done = sched.run()
    assert len(done) == 10
    assert all(r.result_tokens is not None and len(r.result_tokens) == 4
               for r in done)
    assert all(r.met_deadline for r in done)


def test_synth_lm_is_learnable_structure():
    """Order-2 structure: the true next-token entropy is far below uniform."""
    lang = dp.SynthLM(vocab=128, seed=0)
    rng = np.random.default_rng(0)
    x = lang.sample(rng, batch=8, seq=256)
    assert x.shape == (8, 256)
    assert x.min() >= 0 and x.max() < 128
    # determinism given seeds
    x2 = lang.sample(np.random.default_rng(0), batch=8, seq=256)
    np.testing.assert_array_equal(x, dp.SynthLM(vocab=128, seed=0).sample(
        np.random.default_rng(0), 8, 256))


def test_param_spec_divisibility():
    mesh = make_host_mesh()      # axes sizes 1: nothing shards
    spec = sh.param_spec("['blocks']['layers']['ffn']['up']['w']",
                         (4, 64, 128), mesh)
    assert all(s is None for s in spec)


def test_collective_parser_loop_multiplier():
    from repro.launch.dryrun import collective_bytes
    hlo = """
ENTRY %main (p: f32[4]) -> f32[4] {
  %x = f32[16,16] all-gather(%p), dimensions={0}
  %w = (s32[], f32[4]) while(%t), condition=%cond, body=%body.1, backend_config={"known_trip_count":{"n":"7"}}
}

%body.1 (p: f32[4]) -> f32[4] {
  %y = f32[8,8] all-reduce(%p), to_apply=%add
}
"""
    out = collective_bytes(hlo)
    assert out["all-gather"] == 16 * 16 * 4
    assert out["all-reduce"] == 8 * 8 * 4 * 7      # x trip count


def test_dryrun_tiny_mesh_compiles():
    """End-to-end lower+compile of the sharded train step on the host mesh."""
    os.environ.setdefault("XLA_FLAGS", "")
    import dataclasses
    from repro.launch import dryrun as D
    from repro.configs.base import InputShape
    cfg = get_config("gemma-7b").reduced()
    shape = InputShape("tiny_train", 32, 4, "train")
    mesh = make_host_mesh()
    with mesh:
        fn, args = D.build_step(cfg, shape, mesh)
        compiled = fn.lower(*args).compile()
    assert compiled.cost_analysis() is not None
