"""Serving fleet: traffic generation, continuous batching (EDF admission,
slot reuse, drop/degrade), FPX routing across the pool, SLO metrics, and
the wave scheduler's per-request latency / heterogeneous-extra fixes."""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import transformer as T
from repro.serving import fleet as fleet_mod
from repro.serving import (ContinuousBatcher, FleetRouter, LatencyProfile,
                           metrics, pool_candidates, traffic)
from repro.serving.engine import ServingEngine
from repro.serving.scheduler import Request, Scheduler
from repro.serving.traffic import SimRequest


def _eps(cfg, seed=0):
    rng = np.random.default_rng(seed)
    return {f"L{i}.lin{j}": float(rng.uniform(0.05, 0.9))
            for i in range(cfg.n_layers) for j in range(4)}


def _req(rid, *, t=0.0, cls="t", prompt=64, new=8, deadline=1.0, weight=1.0):
    return SimRequest(rid=rid, cls_name=cls, t_arrive=t, prompt_len=prompt,
                      max_new=new, deadline_s=deadline, reward_weight=weight)


@pytest.fixture(scope="module")
def profile():
    cfg = get_config("qwen2.5-1.5b")
    return LatencyProfile(cfg, 4.0)


# -- traffic ----------------------------------------------------------------

def test_traffic_deterministic_and_sorted():
    a = traffic.generate(traffic.scenario("mixed"), 5.0, seed=3)
    b = traffic.generate(traffic.scenario("mixed"), 5.0, seed=3)
    assert [r.t_arrive for r in a] == [r.t_arrive for r in b]
    times = [r.t_arrive for r in a]
    assert times == sorted(times)
    assert all(0.0 <= t < 5.0 for t in times)
    assert {r.cls_name for r in a} == {"trading", "chat"}
    assert [r.rid for r in a] == list(range(len(a)))


def test_bursty_rate_is_mean_preserving():
    cls = traffic.trading_class(rate_hz=50.0)
    n = len(traffic.generate([cls], 60.0, seed=0))
    assert 0.7 * 50 * 60 < n < 1.3 * 50 * 60


# -- continuous batching ----------------------------------------------------

def test_edf_admission_under_contention(profile):
    """With one slot busy, the queued request with the earliest deadline is
    admitted first even though it arrived (and was submitted) last."""
    b = ContinuousBatcher(profile, slots=1, policy="serve")
    blocker = _req(0, deadline=10.0, new=32)
    loose = _req(1, t=0.001, deadline=10.0)
    tight = _req(2, t=0.002, deadline=0.5)
    for r in (blocker, loose, tight):
        b.submit(r)
    b.run()
    assert blocker.t_admit < tight.t_admit < loose.t_admit


def test_slot_reuse_mid_flight(profile):
    """A freed decode slot is reusable immediately — the third request is
    admitted when the short request finishes, while the long one is still
    decoding (no wave barrier)."""
    b = ContinuousBatcher(profile, slots=2, policy="serve")
    short = _req(0, new=2, deadline=10.0)
    long = _req(1, new=40, deadline=10.0)
    third = _req(2, new=2, deadline=10.0)
    for r in (short, long, third):
        b.submit(r)
    b.run()
    assert third.t_admit >= short.t_finish
    assert third.t_admit < long.t_finish
    assert third.t_finish < long.t_finish


def test_degrade_policy_trims_to_deadline(profile):
    """A request whose full decode cannot fit its deadline is truncated to
    the token budget that does fit — and still counts as on-time."""
    step = profile.step_s(1, 64)
    prefill = profile.prefill_s(64)
    b = ContinuousBatcher(profile, slots=1, policy="degrade")
    r = _req(0, prompt=64, new=50, deadline=prefill + 10.5 * step)
    b.submit(r)
    b.run()
    assert 0 < r.tokens_done < 50
    assert r.met_deadline and not r.dropped


def test_drop_policy_rejects_infeasible(profile):
    retired = []
    b = ContinuousBatcher(profile, slots=1, policy="drop",
                          on_retire=retired.append)
    r = _req(0, prompt=64, new=50, deadline=1e-6)
    ok = _req(1, prompt=64, new=4, deadline=10.0)
    b.submit(r)
    b.submit(ok)
    b.run()
    assert r.dropped and r.met_deadline is False and r.tokens_done == 0
    assert not ok.dropped and ok.met_deadline
    assert retired == [r, ok]           # drops retire through the callback too


def test_hit_rate_and_goodput_accounting():
    reqs = [_req(0, deadline=1.0), _req(1, deadline=1.0),
            _req(2, deadline=1.0), _req(3, cls="c", deadline=1.0)]
    reqs[0].t_finish, reqs[0].latency_s = 0.5, 0.5
    reqs[0].met_deadline, reqs[0].reward, reqs[0].tokens_done = True, 0.9, 8
    reqs[1].t_finish, reqs[1].latency_s = 2.0, 2.0
    reqs[1].met_deadline, reqs[1].reward = False, 0.0
    reqs[2].dropped, reqs[2].met_deadline = True, False
    reqs[3].t_finish, reqs[3].latency_s = 0.1, 0.1
    reqs[3].met_deadline, reqs[3].reward = True, 0.5
    reqs[3].tokens_done = 4                          # degraded completion
    rep = metrics.summarize(reqs, horizon_s=10.0)
    assert rep.n == 4 and rep.served == 3 and rep.dropped == 1
    assert rep.degraded == 2            # req1 (0 tokens) and req3 (4 of 8)
    assert rep.hit_rate == pytest.approx(0.5)
    assert rep.goodput == pytest.approx(1.4)
    assert rep.goodput_rate == pytest.approx(0.14)
    assert rep.per_class and rep.per_class["c"].goodput == pytest.approx(0.5)
    assert rep.p50_s == pytest.approx(0.5)


# -- fleet routing ----------------------------------------------------------

@pytest.fixture(scope="module")
def pool():
    fast_cfg = get_config("qwen2.5-1.5b")
    slow_cfg = get_config("qwen2.5-14b")
    return pool_candidates([("qwen2.5-1.5b", fast_cfg, _eps(fast_cfg), 1.0),
                            ("qwen2.5-14b", slow_cfg, _eps(slow_cfg), 0.0)])


def _quality(c):
    return {"qwen2.5-1.5b": 0.6, "qwen2.5-14b": 0.95}[c.model_name]


def test_router_tight_deadline_picks_faster_engine(pool):
    router = FleetRouter(pool, quality=_quality, slots=2)
    tight = _req(0, deadline=0.04, prompt=64, new=8)
    loose = _req(1, deadline=2.0, prompt=64, new=8)
    assert router.dispatch(tight) == 0      # only the 1.5b/gamma=1 point fits
    assert router.dispatch(loose) == 1      # quality wins when the SLO allows


def test_router_slack_accounts_for_backlog(pool):
    """Once the slow engine's queue eats the deadline slack, requests that
    would prefer it spill to the fast engine."""
    router = FleetRouter(pool, quality=_quality, slots=1)
    lat14 = pool[1].latency_s
    picks = [router.dispatch(_req(i, deadline=1.5 * lat14,
                                  prompt=256, new=16))
             for i in range(6)]
    assert picks[0] == 1
    assert 0 in picks                       # later arrivals overflow to fast


def test_fleet_feedback_updates_selector(pool):
    router = FleetRouter(pool, quality=_quality, slots=2)
    arrivals = [_req(i, t=0.05 * i, cls="trading", deadline=0.04,
                     prompt=64, new=6) for i in range(10)]
    out = router.run(arrivals)
    assert len(out) == 10
    sel = router.selectors["trading"]
    # every retirement lands on the dispatched arm, on top of the one
    # warm-start pseudo-observation each arm carries
    assert sel.counts == [11, 1]
    # realized on-time reward holds the fast arm at its quality; the
    # never-dispatched slow arm still carries only its optimistic prior
    assert sel.means[0] == pytest.approx(_quality(pool[0]))
    assert sel.means[1] == pytest.approx(_quality(pool[1]))


def test_fleet_beats_static_baselines_on_mixed_traffic():
    """The acceptance property, at test scale: on a heterogeneous mix the
    FPX fleet router earns strictly more goodput than every equal-capacity
    static single-(model, gamma) deployment."""
    cands = fleet_mod.demo_pool()
    q = fleet_mod.demo_quality
    arrivals = traffic.generate(traffic.scenario("mixed"), 10.0, seed=1)
    fleet_rep = metrics.summarize(
        FleetRouter(cands, quality=q, slots=4).run(
            [a.fresh() for a in arrivals]), 10.0)
    for c in cands:
        static = metrics.summarize(
            FleetRouter([c] * len(cands), quality=q, slots=4).run(
                [a.fresh() for a in arrivals]), 10.0)
        assert fleet_rep.goodput > static.goodput, c.model_name


# -- wave scheduler fixes ---------------------------------------------------

class _FakeEngine:
    """Engine stand-in: deterministic tokens, real latency model."""

    def __init__(self):
        self.latency_cfg = get_config("qwen2.5-1.5b")
        self.avg_bits = 8.0
        self.batches = []

    modeled_latency = ServingEngine.modeled_latency

    def generate(self, batch, *, max_new=16, **kw):
        self.batches.append(batch)
        B = batch["tokens"].shape[0]

        class R:
            new_tokens = np.zeros((B, max_new), np.int32)
            latency_s = 123.0
        return R()


def test_scheduler_per_request_latency():
    eng = _FakeEngine()
    sched = Scheduler(eng, batch_slots=4)
    short = Request(rid=0, prompt=np.zeros(8, np.int32), max_new=4,
                    deadline_s=10.0)
    long = Request(rid=1, prompt=np.zeros(64, np.int32), max_new=16,
                   deadline_s=10.0)
    sched.submit(short)
    sched.submit(long)
    sched.run()
    # each request is charged its own shape, not the padded wave's
    assert short.latency_s == pytest.approx(eng.modeled_latency(8, 4))
    assert long.latency_s == pytest.approx(eng.modeled_latency(64, 16))
    assert short.latency_s < long.latency_s
    assert short.met_deadline and long.met_deadline


def test_scheduler_splits_heterogeneous_extra_waves():
    eng = _FakeEngine()
    sched = Scheduler(eng, batch_slots=4)
    plain1 = Request(rid=0, prompt=np.zeros(8, np.int32), max_new=2)
    vision = Request(rid=1, prompt=np.zeros(8, np.int32), max_new=2,
                     extra={"vision": np.zeros((2, 3), np.float32)})
    plain2 = Request(rid=2, prompt=np.zeros(8, np.int32), max_new=2)
    for r in (plain1, vision, plain2):
        sched.submit(r)
    first = sched.step()
    assert [r.rid for r in first] == [0, 2]         # homogeneous wave
    second = sched.step()
    assert [r.rid for r in second] == [1]
    assert "vision" in eng.batches[1]
    assert all(r.result_tokens is not None for r in (plain1, vision, plain2))


def test_make_batch_rejects_heterogeneous_extras():
    eng = _FakeEngine()
    sched = Scheduler(eng, batch_slots=4)
    a = Request(rid=0, prompt=np.zeros(4, np.int32))
    b = Request(rid=1, prompt=np.zeros(4, np.int32),
                extra={"audio": np.zeros(3, np.float32)})
    with pytest.raises(ValueError, match="heterogeneous"):
        sched._make_batch([a, b])


# -- serving-layer bug-fix regressions --------------------------------------

def test_step_s_bucket_memoization_order_independent():
    """step_s memoizes per context bucket; the cached cost must be the
    bucket-representative's, not whichever exact context was seen first."""
    cfg = get_config("qwen2.5-1.5b")
    a = LatencyProfile(cfg, 4.0)
    b = LatencyProfile(cfg, 4.0)
    ctxs = [100, 70, 127, 65]                  # all land in bucket 1
    for c in ctxs:
        a.step_s(2, c)
    for c in reversed(ctxs):
        b.step_s(2, c)
    for c in ctxs:
        assert a.step_s(2, c) == b.step_s(2, c)
    # and the memoized value is the bucket-representative evaluation
    from repro.core import latency as lat_mod
    rep = lat_mod.step_latency(cfg, n_tokens=2, context=64, w_bits=4.0)
    assert a.step_s(2, 100) == pytest.approx(rep)


def test_degraded_budget_reprojection_invariant(profile):
    """The degraded token budget must itself re-project inside the deadline
    (fixed point), for any shape — the invariant the old single-shot trim
    never checked."""
    from repro.serving.continuous import degraded_budget, projected_finish
    rng = np.random.default_rng(0)
    for _ in range(50):
        req = _req(0, prompt=int(rng.integers(16, 512)),
                   new=int(rng.integers(1, 128)),
                   deadline=float(rng.uniform(1e-5, 2e-3)))
        for n_active in (1, 3):
            n = degraded_budget(profile, 0.0, n_active, req)
            assert 0 <= n <= req.max_new
            if n >= 1:
                assert projected_finish(profile, 0.0, n_active, req, n) \
                    <= req.deadline_abs
    # degraded admissions honored end-to-end: truncated but on time
    b = ContinuousBatcher(profile, slots=1, policy="degrade")
    r = _req(1, prompt=300, new=120,
             deadline=profile.prefill_s(300) + 9.5 * profile.step_s(1, 300))
    b.submit(r)
    b.run()
    assert not r.dropped and r.met_deadline and 0 < r.tokens_done < 120


def test_generate_sampling_defaults_key():
    """temp > 0 with key=None must not crash in jax.random.split; it falls
    back to a fixed seed and matches the explicit PRNGKey(0) run."""
    cfg = get_config("qwen-sim-1.5b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    eng = ServingEngine(params, cfg, max_ctx=32)
    batch = {"tokens": np.ones((1, 8), np.int32)}
    res_default = eng.generate(batch, max_new=4, temp=0.8)
    res_seeded = eng.generate(batch, max_new=4, temp=0.8,
                              key=jax.random.PRNGKey(0))
    assert np.array_equal(np.asarray(res_default.new_tokens),
                          np.asarray(res_seeded.new_tokens))
    res_other = eng.generate(batch, max_new=4, temp=0.8,
                             key=jax.random.PRNGKey(7))
    assert res_other.new_tokens.shape == (1, 4)


def test_batcher_accepts_request_without_slo(profile):
    """The unified contract: a scheduler Request with deadline_s=None
    (no SLO) runs through the analytic batcher — deadline_abs projects to
    +inf instead of crashing the met-deadline comparison."""
    b = ContinuousBatcher(profile, slots=1, policy="serve")
    r = Request(rid=0, prompt=np.arange(8, dtype=np.int32), max_new=4)
    b.submit(r)
    b.run()
    assert r.met_deadline and r.tokens_done == 4 and not r.dropped


def test_drain_idle_advances_clock_router_fairness(profile):
    """An idle engine drained to a horizon must advance its clock to it —
    engines compared by the router after the same drain have to agree on
    "now" regardless of who served traffic and who idled."""
    busy = ContinuousBatcher(profile, slots=2, policy="serve")
    idle = ContinuousBatcher(profile, slots=2, policy="serve")
    busy.submit(_req(0, new=4, deadline=10.0))
    horizon = 0.5
    busy.drain(until=horizon)
    idle.drain(until=horizon)
    assert idle.t == pytest.approx(horizon)    # was: stuck at 0.0
    assert busy.t >= horizon
    assert busy.completed and not busy.pending
    # an engine whose pending work lies beyond the horizon idles to it too
    late = ContinuousBatcher(profile, slots=2, policy="serve")
    late.submit(_req(1, t=5.0, new=4, deadline=10.0))
    late.drain(until=horizon)
    assert late.t == pytest.approx(horizon)
    # fairness: idle engines agree — no phantom backlog, no stale clock
    assert idle.backlog_s(horizon) == 0.0
    assert late.t == idle.t


def test_scheduler_real_engine_ragged_prompts():
    """Integration: the live engine path still serves ragged waves and the
    per-request latency comes from each request's own shape."""
    cfg = get_config("qwen-sim-1.5b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    eng = ServingEngine(params, cfg, max_ctx=64)
    sched = Scheduler(eng, batch_slots=4)
    rng = np.random.default_rng(0)
    lens = [8, 20]
    for rid, n in enumerate(lens):
        sched.submit(Request(rid=rid,
                             prompt=rng.integers(0, cfg.vocab, n).astype(np.int32),
                             max_new=4, deadline_s=10.0))
    done = sched.run()
    assert len(done) == 2
    assert done[0].latency_s < done[1].latency_s
    assert all(len(r.result_tokens) == 4 for r in done)
