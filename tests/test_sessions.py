"""Sessions, prefix reuse, and barge-in — the PR 8 differential suite.

The contract under test: with the prefix cache on, a paged engine serving
requests that share a prompt prefix produces tokens **identical** to the
contiguous-cache wave oracle serving each request alone — the shared
pages plus copy-on-write are invisible to the numerics — while the pool's
refcounted accounting never leaks, double-frees, or dangles a page, even
under mid-decode barge-in cancellation of a lane that shares pages with
co-resident lanes.

Locked by the same cross-path harness as tests/test_hybrid_paged.py
(``REPRO_PAGED_MODES`` selects the paged-attention implementation), plus
a refcount-aware page-accounting property test with random share / adopt
/ CoW / cancel sequences, and check_trace negatives proving the trace
auditor rejects the failure modes (double-free of a shared page, share
of a dead page).
"""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from conftest import (make_requests, pallas_modes, run_paged,
                      run_wave_reference, servable_smoke_configs,
                      smoke_params)
from repro.configs import get_config
from repro.obs import trace as tr_mod
from repro.obs.check_trace import check
from repro.serving import metrics as metrics_mod
from repro.serving import traffic
from repro.serving.continuous import ContinuousBatcher, LatencyProfile
from repro.serving.kv_cache import (CACHE_SLOT, DUMMY_PAGE, PagedKVCache,
                                    PrefixCache)
from repro.serving.scheduler import Request, Scheduler

SERVABLE = servable_smoke_configs()
#: prefix sharing requires all-full-attention stacks; pick the dense ones
DENSE = [(n, c) for n, c in SERVABLE if not c.sliding_window]
NAME, CFG = DENSE[0]

MAX_NEW = 4
PREFIX_LEN = 27          # deliberately page-unaligned for page_size=8
TAILS = (5, 9, 14)


def _shared_prefix_requests(cfg, *, max_new=MAX_NEW, seed=3):
    """Requests sharing a PREFIX_LEN-token prefix with distinct tails,
    declaring the shared span via ``prefix_keys`` (what session traffic
    does) so the engine caches the prefix, not just whole prompts."""
    rng = np.random.default_rng(seed)
    shared = rng.integers(0, cfg.vocab, PREFIX_LEN).astype(np.int32)
    reqs = []
    for i, t in enumerate(TAILS):
        tail = rng.integers(0, cfg.vocab, t).astype(np.int32)
        r = Request(rid=i, prompt=np.concatenate([shared, tail]),
                    max_new=max_new, deadline_s=100.0)
        r.prefix_keys = (("shared", PREFIX_LEN),)
        reqs.append(r)
    return reqs


def _total_pages(cache):
    return sum(n - 1 for n in cache._group_pages.values())


# -- the tentpole acceptance: shared-prefix token identity --------------------

@pytest.mark.parametrize("use_pallas", pallas_modes())
@pytest.mark.parametrize("chunk", [None, 8])
@pytest.mark.parametrize("slots", [1, 3])
def test_shared_prefix_token_identity(chunk, slots, use_pallas):
    """Adopted prefix pages + resume prefill of the remainder == the wave
    oracle's from-scratch prefill, monolithic and chunked, sequential
    (slots=1: every later request hits) and co-resident (slots=3)."""
    params = smoke_params(NAME)
    want = _shared_prefix_requests(CFG)
    run_wave_reference(params, CFG, want)
    reqs, eng = run_paged(params, CFG, _shared_prefix_requests(CFG),
                          page_size=8, chunk=chunk, slots=slots,
                          use_pallas=use_pallas, prefix_cache=True)
    for w, r in zip(want, reqs):
        assert r.result_tokens is not None, r.rid
        assert np.array_equal(w.result_tokens, r.result_tokens), \
            (chunk, slots, use_pallas, r.rid)
    if slots == 1 or chunk is None:
        # sequential service (or synchronous monolithic prefills): every
        # later request finds the prefix warm.  slots=3 + chunked admits
        # all three before any prefill completes — legitimately no hits
        # (in-flight prefills are unpublishable: their pages are still
        # being written).
        assert eng.prefix.hits >= 2, eng.prefix.hits
    # cache holdings are the only live pages; releasing them restores the
    # full pool (conservation under refcounting)
    eng.prefix.clear()
    assert eng.cache.free_pages == _total_pages(eng.cache)


@pytest.mark.parametrize("use_pallas", pallas_modes())
def test_prefix_cache_off_is_bit_identical_noop(use_pallas):
    """prefix_cache=False (the default everywhere) must not change a
    single token vs. the historical engine — committed benchmark CSVs
    depend on it."""
    params = smoke_params(NAME)
    base, _ = run_paged(params, CFG, _shared_prefix_requests(CFG),
                        page_size=8, use_pallas=use_pallas)
    off, eng = run_paged(params, CFG, _shared_prefix_requests(CFG),
                         page_size=8, use_pallas=use_pallas,
                         prefix_cache=False)
    assert eng.prefix is None
    for b, r in zip(base, off):
        assert np.array_equal(b.result_tokens, r.result_tokens)


def test_prefix_cache_rejects_windowed_stacks():
    windowed = [(n, c) for n, c in SERVABLE if c.sliding_window]
    name, cfg = windowed[0]
    with pytest.raises(ValueError, match="full-attention"):
        run_paged(smoke_params(name), cfg, make_requests(cfg, (9,)),
                  prefix_cache=True)


# -- refcount / copy-on-write unit semantics ---------------------------------

def _zero_prefill_kv(cfg, cache, S):
    import jax.numpy as jnp
    return {g.name: {"k": jnp.zeros((len(g.layers), S, cfg.n_kv_heads,
                                     cfg.head_dim)),
                     "v": jnp.zeros((len(g.layers), S, cfg.n_kv_heads,
                                     cfg.head_dim))}
            for g in cache.groups}


def test_share_adopt_cow_refcount_lifecycle():
    """The full life of a shared unaligned prefix: donor demotion, CoW on
    the donor's next write, adoption by a second lane, CoW on the
    adopter's first write, and frees that only return pages at refcount
    zero."""
    cfg = CFG
    ps = 4
    cache = PagedKVCache(cfg, slots=2, n_pages=24, page_size=ps, max_ctx=32)
    cache.alloc(0, 14)                       # 10 prompt + 4 decode budget
    cache.write_prefill(0, _zero_prefill_kv(cfg, cache, 10))
    snap = cache.share_prefix(0, 10)         # 10 tokens -> 3 pages, page 2
    for g, plist in snap["pages"].items():   # partially covered (boundary)
        assert len(plist) == 3
        for p in plist:
            assert cache.refcount(g, p) == 2   # donor + snapshot
    # the donor's live write page was demoted: its next write must CoW
    g0 = cache.groups[0].name
    assert 2 in cache._shared[g0][0] and 2 not in cache._owned[g0][0]
    boundary = snap["pages"][g0][2]
    cache.prepare_tokens(0, 1)               # donor decodes: CoW
    assert cache.refcount(g0, boundary) == 1            # snapshot only
    assert cache._owned[g0][0][2] != boundary           # fresh page
    # a second lane adopts the snapshot
    cache.alloc(1, 20, adopt=snap, adopt_len=10)
    assert int(cache.pos[1]) == 10
    assert cache.refcount(g0, boundary) == 2            # snapshot + lane 1
    cache.prepare_tokens(1, 4)               # adopter writes: CoW again
    assert cache.refcount(g0, boundary) == 1
    # frees drop references; the snapshot keeps its pages live
    cache.free(0)
    for g, plist in snap["pages"].items():
        for p in plist:
            assert cache.refcount(g, p) >= 1
    cache.free(1)
    assert cache.free_pages < _total_pages(cache)       # snapshot still held
    cache.release_snapshot(snap)
    assert cache.free_pages == _total_pages(cache)


def test_prefix_cache_lookup_is_strict_and_verified():
    """*Adoption* is strictly shorter than the prompt (at least one token
    must remain to prefill — the first output token is sampled from the
    prefill logits), but an exact-length match IS adoptable at all but
    its last token: that is what lets a wave of identical prompts reuse
    the leader's prefill (the in-flight registry fix).  probe() matches
    lookup() without perturbing LRU order, and a hash key never serves
    mismatched tokens."""
    cache = PagedKVCache(CFG, slots=2, n_pages=24, page_size=4, max_ctx=32)
    pc = PrefixCache(cache)
    toks = np.arange(12, dtype=np.int32)
    cache.alloc(0, 16)
    cache.write_prefill(0, _zero_prefill_kv(CFG, cache, 12))
    assert pc.insert(0, toks, 12)
    snap, n = pc.lookup(toks)
    assert n == 11 and snap is not None     # exact match: adopt all but 1
    assert pc.probe(toks) == 11
    assert pc.probe(toks[:1]) == 0          # nothing shorter than 1 adoptable
    longer = np.concatenate([toks, [99]]).astype(np.int32)
    order_before = list(pc._entries)
    assert pc.probe(longer) == 12
    assert list(pc._entries) == order_before
    snap, n = pc.lookup(longer)
    assert n == 12 and snap is not None
    different = longer.copy()
    different[3] = 77                                   # same length, other
    assert pc.probe(different) == 0                     # tokens: verified
    cache.free(0)
    pc.clear()
    assert cache.free_pages == _total_pages(cache)


def test_prefix_cache_lru_eviction_bounded_by_max_pages():
    cache = PagedKVCache(CFG, slots=2, n_pages=24, page_size=4, max_ctx=32)
    n_groups = len(cache.groups)
    pc = PrefixCache(cache, max_pages=2 * n_groups)     # room for one entry
    for slot, base in ((0, 0), (1, 100)):
        toks = np.arange(base, base + 8, dtype=np.int32)
        cache.alloc(slot, 12)
        cache.write_prefill(slot, _zero_prefill_kv(CFG, cache, 8))
        assert pc.insert(slot, toks, 8)
        cache.free(slot)
    assert len(pc) == 1                                 # first entry evicted
    assert pc.held_pages <= 2 * n_groups
    assert pc.probe(np.arange(0, 9, dtype=np.int32)) == 0
    assert pc.probe(np.arange(100, 109, dtype=np.int32)) == 8
    pc.clear()
    assert cache.free_pages == _total_pages(cache)


# -- barge-in cancellation ---------------------------------------------------

@pytest.mark.parametrize("use_pallas", pallas_modes())
def test_barge_in_mid_decode_keeps_corunner_identical(use_pallas):
    """Cancelling a lane mid-decode reclaims its private pages, merely
    decrements the shared prefix pages, and leaves the co-resident lane's
    tokens identical to the oracle — replayed through check_trace."""
    params = smoke_params(NAME)
    max_new = 10
    want = _shared_prefix_requests(CFG, max_new=max_new)
    run_wave_reference(params, CFG, want)
    # dry run to learn the victim's decode window on the analytic clock
    dry, _ = run_paged(params, CFG, _shared_prefix_requests(CFG,
                                                            max_new=max_new),
                       page_size=8, use_pallas=use_pallas, prefix_cache=True)
    victim = dry[1]
    assert victim.t_first_token is not None
    t_cancel = victim.t_first_token + 0.5 * (victim.t_finish
                                             - victim.t_first_token)
    reqs = _shared_prefix_requests(CFG, max_new=max_new)
    reqs[1].t_cancel = t_cancel
    tr = tr_mod.Tracer()
    reqs, eng = run_paged(params, CFG, reqs, page_size=8,
                          use_pallas=use_pallas, prefix_cache=True,
                          tracer=tr)
    r = reqs[1]
    assert r.cancelled and not r.dropped
    assert 0 < r.tokens_done < max_new
    # partial output is the oracle's prefix (barge-in loses no tokens)
    assert np.array_equal(want[1].result_tokens[:r.tokens_done],
                          r.result_tokens)
    for i in (0, 2):                         # co-runners: token-identical
        assert not reqs[i].cancelled
        assert np.array_equal(want[i].result_tokens, reqs[i].result_tokens)
    assert any(e.name == tr_mod.REQ_CANCEL for e in tr.events)
    assert check(tr.events) == []            # incl. refcounted conservation
    eng.prefix.clear()
    assert eng.cache.free_pages == _total_pages(eng.cache)


@pytest.mark.parametrize("use_pallas", pallas_modes())
def test_barge_in_racing_prefill_chunk_reclaims_cleanly(use_pallas):
    """A cancel landing *mid-chunked-prefill* — after admission, before
    the prompt is absorbed — must tear the lane down with zero emitted
    tokens, reclaim every page it held (prefix refs merely decremented),
    and leave co-resident lanes token-identical.  The trace replay proves
    the pool closes; the cancelled rid still retires exactly once."""
    params = smoke_params(NAME)
    want = _shared_prefix_requests(CFG)
    run_wave_reference(params, CFG, want)
    # dry run to find the victim's prefill window under chunk=8
    dry, _ = run_paged(params, CFG, _shared_prefix_requests(CFG),
                       page_size=8, chunk=8, slots=3,
                       use_pallas=use_pallas, prefix_cache=True)
    victim = dry[1]
    assert victim.t_admit is not None and victim.t_prefill_done is not None
    assert victim.t_prefill_done > victim.t_admit, \
        "chunked prefill must leave an open admit->absorbed window"
    t_cancel = victim.t_admit + 0.5 * (victim.t_prefill_done
                                       - victim.t_admit)
    reqs = _shared_prefix_requests(CFG)
    reqs[1].t_cancel = t_cancel
    tr = tr_mod.Tracer()
    reqs, eng = run_paged(params, CFG, reqs, page_size=8, chunk=8,
                          slots=3, use_pallas=use_pallas,
                          prefix_cache=True, tracer=tr)
    r = reqs[1]
    assert r.cancelled and not r.dropped
    assert r.tokens_done == 0 and r.t_first_token is None
    for i in (0, 2):                         # co-runners: token-identical
        assert not reqs[i].cancelled
        assert np.array_equal(want[i].result_tokens, reqs[i].result_tokens)
    assert any(e.name == tr_mod.REQ_CANCEL for e in tr.events)
    assert check(tr.events) == []            # conservation: no leaked pages
    eng.prefix.clear()
    assert eng.cache.free_pages == _total_pages(eng.cache)


def test_analytic_barge_in_before_admission_is_a_miss(profile):
    """A request cancelled while still queued retires as cancelled (not
    dropped), with no first token and a missed deadline."""
    b = ContinuousBatcher(profile, slots=1, policy="serve")
    blocker = traffic.SimRequest(rid=0, cls_name="t", t_arrive=0.0,
                                 prompt_len=64, max_new=64, deadline_s=10.0)
    queued = traffic.SimRequest(rid=1, cls_name="t", t_arrive=0.0,
                                prompt_len=64, max_new=8, deadline_s=10.0,
                                t_cancel=1e-4)
    b.submit(blocker)
    b.submit(queued)
    out = b.run()
    r = next(x for x in out if x.rid == 1)
    assert r.cancelled and not r.dropped
    assert r.tokens_done == 0 and r.t_first_token is None
    assert r.met_deadline is False
    assert next(x for x in out if x.rid == 0).tokens_done == 64


def test_wave_scheduler_sweeps_cancelled_before_launch():
    """The wave path never launches a request whose cancel time passed
    before its wave — waves are atomic, so that is the only barge-in the
    wave engine honors."""
    from repro.serving.engine import ServingEngine

    params = smoke_params(NAME)
    sched = Scheduler(ServingEngine(params, CFG, max_ctx=64), batch_slots=1)
    reqs = make_requests(CFG, (9, 7), max_new=4)
    reqs[1].t_cancel = 1e-6                  # cancelled during wave 0
    for r in reqs:
        sched.submit(r)
    done = sched.run()
    assert len(done) == 2
    assert reqs[0].result_tokens is not None and not reqs[0].cancelled
    assert reqs[1].cancelled and reqs[1].result_tokens is None
    assert reqs[1].met_deadline is False


# -- session traffic ---------------------------------------------------------

def test_session_traffic_deterministic_and_nested():
    cls = traffic.support_sessions(rate_hz=1.5)
    a = traffic.generate_sessions([cls], 10.0, seed=7)
    b = traffic.generate_sessions([cls], 10.0, seed=7)
    assert [(r.session, r.turn, r.prompt_len, r.t_arrive) for r in a] \
        == [(r.session, r.turn, r.prompt_len, r.t_arrive) for r in b]
    assert [r.t_arrive for r in a] == sorted(r.t_arrive for r in a)
    assert [r.rid for r in a] == list(range(len(a)))
    by_session = {}
    for r in a:
        by_session.setdefault(r.session, []).append(r)
    multi = [v for v in by_session.values() if len(v) > 1]
    assert multi, "no multi-turn session in 10s of traffic"
    for turns in multi:
        assert [r.turn for r in turns] == list(range(len(turns)))
        for prev, nxt in zip(turns, turns[1:]):
            assert nxt.prompt_len > prev.prompt_len     # turns accumulate
            assert nxt.t_arrive > prev.t_arrive
            assert nxt.sys_len == prev.sys_len
            # next turn's prompt literally extends the previous turn's
            p = traffic.session_prompt_tokens(prev, vocab=1000)
            q = traffic.session_prompt_tokens(nxt, vocab=1000)
            assert np.array_equal(q[:len(p)], p)
    # the system prompt is shared across sessions of the class
    sys_groups = {}
    for r in a:
        sys_groups.setdefault(r.sys_len, []).append(r)
    wide = [v for v in sys_groups.values()
            if len({x.session for x in v}) > 1]
    if wide:
        toks = [traffic.session_prompt_tokens(x, vocab=1000)[:x.sys_len]
                for x in wide[0][:2]]
        assert np.array_equal(toks[0], toks[1])
    # prefix_keys declare exactly the reusable spans
    for r in a:
        (k_sys, n_sys), (k_sess, n_sess) = r.prefix_keys
        assert k_sys.endswith("/sys") and n_sys == r.sys_len
        assert k_sess == r.session and n_sess == r.prompt_len


def test_session_traffic_carries_slos_and_barge_in():
    cls = traffic.support_sessions(rate_hz=2.0)
    reqs = traffic.generate_sessions([cls], 20.0, seed=1)
    assert all(r.ttft_deadline_s is not None for r in reqs)
    assert all(r.deadline_s >= r.ttft_deadline_s for r in reqs)
    cancels = [r for r in reqs if r.t_cancel is not None]
    frac = len(cancels) / len(reqs)
    assert 0.02 < frac < 0.5                 # ~barge_in_frac of turns
    assert all(r.t_cancel > r.t_arrive for r in cancels)


@pytest.fixture(scope="module")
def profile():
    return LatencyProfile(get_config("qwen2.5-1.5b"), 4.0)


def test_analytic_prefix_cache_cuts_ttft(profile):
    """The batcher's warm-prefix mirror prices session turns' skipped
    prefill: TTFT p50 drops vs. the same traffic without sharing, token
    budgets and capacity equal."""
    cls = traffic.support_sessions(rate_hz=3.0)
    arrivals = traffic.generate_sessions([cls], 15.0, seed=2)
    reps = {}
    for on in (False, True):
        b = ContinuousBatcher(profile, slots=4, policy="serve",
                              prefix_cache=on)
        for r in arrivals:
            b.submit(r.fresh())
        reps[on] = metrics_mod.summarize(b.run(), 15.0)
    assert reps[True].ttft_p50_s < reps[False].ttft_p50_s
    assert reps[True].served >= reps[False].served
    # the new aggregates exist and are sane
    assert reps[True].cancelled >= 0
    assert 0.0 <= reps[True].ttft_hit_rate <= 1.0


def test_metrics_cancelled_disjoint_from_dropped_and_degraded(profile):
    b = ContinuousBatcher(profile, slots=1, policy="serve")
    blocker = traffic.SimRequest(rid=0, cls_name="t", t_arrive=0.0,
                                 prompt_len=64, max_new=32, deadline_s=10.0)
    queued = traffic.SimRequest(rid=1, cls_name="t", t_arrive=0.0,
                                prompt_len=64, max_new=8, deadline_s=10.0,
                                t_cancel=1e-4)
    b.submit(blocker)
    b.submit(queued)
    rep = metrics_mod.summarize(b.run(), 1.0)
    assert rep.cancelled == 1
    assert rep.dropped == 0
    assert rep.degraded == 0                 # cancelled != degraded


def test_ttft_admission_drops_hopeless_first_tokens(profile):
    """Under policy='drop', a request whose projected first token already
    misses its TTFT budget is rejected at admission — degrading cannot
    speed up the first token."""
    b = ContinuousBatcher(profile, slots=1, policy="drop")
    hopeless = traffic.SimRequest(rid=0, cls_name="t", t_arrive=0.0,
                                  prompt_len=256, max_new=4,
                                  deadline_s=10.0, ttft_deadline_s=1e-6)
    b.submit(hopeless)
    b.run()
    assert b.dropped and b.dropped[0].rid == 0
    assert b.dropped[0].tokens_done == 0


# -- fleet routing -----------------------------------------------------------

def _eps(cfg, seed=0):
    rng = np.random.default_rng(seed)
    return {f"L{i}.lin{j}": float(rng.uniform(0.05, 0.9))
            for i in range(cfg.n_layers) for j in range(4)}


def test_router_prefers_engine_with_warm_prefix():
    """Two identical engines; one has the session's prefix warm — the
    discounted service estimate routes the next turn there."""
    from repro.serving.fleet import FleetRouter, pool_candidates

    cfg = get_config("qwen2.5-1.5b")
    cand = pool_candidates([("qwen2.5-1.5b", cfg, _eps(cfg), 1.0)])[0]
    router = FleetRouter([cand, cand], quality=lambda c: 1.0, slots=2)
    for e in router.engines:
        e.prefix_cache = True
    router.engines[1]._warm["sess/a"] = 192
    req = traffic.SimRequest(rid=0, cls_name="t", t_arrive=0.0,
                             prompt_len=256, max_new=8, deadline_s=5.0,
                             prefix_keys=(("sess/a", 192),))
    assert router.dispatch(req) == 1


def test_router_ttft_slack_excludes_slow_first_tokens():
    """With a TTFT budget set, an engine whose projected first token
    misses it is excluded even when its completion deadline would fit;
    when no engine fits the TTFT budget, the completion rule decides."""
    from repro.serving.fleet import FleetRouter, pool_candidates

    fast = get_config("qwen2.5-1.5b")
    slow = get_config("qwen2.5-14b")
    cands = pool_candidates([("qwen2.5-1.5b", fast, _eps(fast), 1.0),
                             ("qwen2.5-14b", slow, _eps(slow), 0.0)])
    quality = lambda c: {"qwen2.5-1.5b": 0.6, "qwen2.5-14b": 0.95}[
        c.model_name]
    router = FleetRouter(cands, quality=quality, slots=2)
    slow_ttft = (router.engines[1].profile.prefill_s(256)
                 + router.engines[1].profile.tok_s(1, 257))
    fast_ttft = (router.engines[0].profile.prefill_s(256)
                 + router.engines[0].profile.tok_s(1, 257))
    assert fast_ttft < slow_ttft
    pick = router.dispatch(traffic.SimRequest(
        rid=0, cls_name="t", t_arrive=0.0, prompt_len=256, max_new=8,
        deadline_s=30.0, ttft_deadline_s=0.5 * (fast_ttft + slow_ttft)))
    assert pick == 0                         # quality said 1; TTFT said 0
    pick = router.dispatch(traffic.SimRequest(
        rid=1, cls_name="t", t_arrive=10.0, prompt_len=256, max_new=8,
        deadline_s=30.0, ttft_deadline_s=1e-9))
    assert pick == 1                         # nobody fits: quality rules


# -- check_trace negatives ---------------------------------------------------

def _ev(name, t, track, **args):
    return tr_mod.Event("instant", name, t, None, track, args, 0.0)


def _pool_prelude(t=0.0):
    return [_ev(tr_mod.POOL_CONFIG, t, "pool", groups={"layers": 4},
                page_size=4, slots=2)]


def test_check_trace_rejects_double_free_of_shared_page():
    events = _pool_prelude() + [
        _ev(tr_mod.PAGE_RESERVE, 0.0, "pool", group="layers", slot=0,
            pages=1),
        _ev(tr_mod.PAGE_ALLOC, 0.0, "pool", group="layers", page=1, slot=0),
        _ev(tr_mod.PAGE_SHARE, 0.1, "pool", group="layers", page=1, slot=1,
            refs=2),
        _ev(tr_mod.PAGE_FREE, 0.2, "pool", group="layers", page=1, slot=1,
            refs=1),
        _ev(tr_mod.PAGE_FREE, 0.3, "pool", group="layers", page=1, slot=1,
            refs=0),
    ]
    errs = check(events)
    assert any("double free" in e for e in errs), errs


def test_check_trace_rejects_share_of_dead_page():
    events = _pool_prelude() + [
        _ev(tr_mod.PAGE_SHARE, 0.1, "pool", group="layers", page=2, slot=1,
            refs=1),
    ]
    errs = check(events)
    assert any("not live" in e for e in errs), errs


def test_check_trace_accepts_refcounted_share_lifecycle():
    """Alloc -> share (cache + lane) -> frees in any holder order -> free
    at refcount zero: a legal trace, conservation intact."""
    events = _pool_prelude() + [
        _ev(tr_mod.REQ_ADMIT, 0.0, "queue", rid=0),
        _ev(tr_mod.PAGE_RESERVE, 0.0, "pool", group="layers", slot=0,
            pages=1),
        _ev(tr_mod.PAGE_ALLOC, 0.0, "pool", group="layers", page=1, slot=0),
        _ev(tr_mod.PAGE_SHARE, 0.1, "pool", group="layers", page=1,
            slot=CACHE_SLOT, refs=2),
        _ev(tr_mod.PAGE_SHARE, 0.2, "pool", group="layers", page=1, slot=1,
            refs=3),
        _ev(tr_mod.PAGE_FREE, 0.3, "pool", group="layers", page=1, slot=0,
            refs=2),
        _ev(tr_mod.PAGE_RESERVE, 0.3, "pool", group="layers", slot=0,
            pages=0),
        _ev(tr_mod.PAGE_FREE, 0.4, "pool", group="layers", page=1, slot=1,
            refs=1),
        _ev(tr_mod.PAGE_FREE, 0.5, "pool", group="layers", page=1,
            slot=CACHE_SLOT, refs=0),
        _ev(tr_mod.REQ_CANCEL, 0.6, "queue", rid=0),
    ]
    assert check(events) == []


# -- refcounted page-accounting property test --------------------------------

def _rc_invariants(cache, pc):
    """Conservation under refcounting: every group's free + live pages
    partition the pool, and each live page's refcount equals its holder
    count (lanes' owned + shared, plus prefix-cache snapshot holdings,
    with multiplicity)."""
    holders = {}
    for g in cache.groups:
        for s in range(cache.slots):
            for p in cache._owned[g.name][s].values():
                holders[(g.name, p)] = holders.get((g.name, p), 0) + 1
            for p in cache._shared[g.name][s].values():
                holders[(g.name, p)] = holders.get((g.name, p), 0) + 1
    for e in pc._entries.values():
        for gname, plist in e["snap"]["pages"].items():
            for p in plist:
                holders[(gname, p)] = holders.get((gname, p), 0) + 1
    for g in cache.groups:
        n_pg = cache._group_pages[g.name]
        free = cache._free[g.name]
        live = {p for (gn, p) in holders if gn == g.name}
        assert len(free) == len(set(free)), g.name
        assert not set(free) & live, g.name
        assert set(free) | live == set(range(1, n_pg)), g.name
        for p in range(1, n_pg):
            assert cache.refcount(g.name, p) \
                == holders.get((g.name, p), 0), (g.name, p)
        assert cache.available(g) >= 0, g.name
        for s in range(cache.slots):
            assert len(cache._owned[g.name][s]) \
                <= int(cache._reserved[g.name][s]), (g.name, s)


@settings(max_examples=15)
@given(st.integers(min_value=0, max_value=10_000))
def test_refcounted_accounting_property(seed):
    """Random admit (with prefix adoption when the cache hits) / insert /
    decode (CoW on shared write pages) / barge-in free / evict sequences
    never break refcount conservation, reservations, or the final
    all-free state.  Prompts draw from a shared base stream so hits are
    common, exercising adoption + CoW, not just exclusive pages."""
    rng = np.random.default_rng(seed)
    cfg = CFG
    ps = int(rng.choice([3, 4, 8]))
    cache = PagedKVCache(cfg, slots=3, n_pages=int(rng.integers(8, 28)),
                         page_size=ps, max_ctx=48)
    pc = PrefixCache(cache, max_pages=int(rng.integers(4, 24)))
    base = rng.integers(0, 50, 48).astype(np.int32)
    live = {}                    # slot -> [total, prompt, base_len, toks]
    for _ in range(60):
        op = rng.integers(0, 5)
        if op == 0 and len(live) < cache.slots:          # admit
            slot = next(s for s in range(cache.slots) if s not in live)
            total = int(rng.integers(4, cache.max_ctx + 1))
            prompt = int(rng.integers(2, total))
            k = int(rng.integers(1, prompt + 1))         # base-prefix len
            toks = np.concatenate(
                [base[:k],
                 rng.integers(50, 100, prompt - k)]).astype(np.int32)
            snap, cached = pc.lookup(toks)
            if not cache.can_admit(total, None, cached):
                continue
            cache.alloc(slot, total, adopt=snap if cached else None,
                        adopt_len=cached)
            if cached:                                   # resume remainder
                cache.prepare_tokens(slot, prompt - cached)
                cache.advance(slot, prompt - cached)
            else:
                cache.write_prefill(
                    slot, _zero_prefill_kv(cfg, cache, prompt))
            live[slot] = [total, prompt, k, toks]
        elif op == 1 and live:                           # publish prefix
            slot = int(rng.choice(list(live)))
            total, prompt, k, toks = live[slot]
            pc.insert(slot, toks, min(k, prompt))
        elif op == 2 and live:                           # decode one token
            slot = int(rng.choice(list(live)))
            total, prompt, k, toks = live[slot]
            if int(cache.pos[slot]) < total:
                cache.prepare_tokens(slot, 1)
                cache.advance(slot, 1)
        elif op == 3 and live:                           # retire / barge-in
            slot = int(rng.choice(list(live)))
            cache.free(slot)
            del live[slot]
        elif op == 4:
            pc.evict_lru()
        _rc_invariants(cache, pc)
    for slot in list(live):
        cache.free(slot)
    pc.clear()
    _rc_invariants(cache, pc)
    assert cache.free_pages == _total_pages(cache)
    assert cache.utilization() == pytest.approx(0.0)
