"""Multi-host sharded fleet: the DCN/ICI clock terms, tensor-parallel
profile pricing, network-aware routing, and the sharded-vs-unsharded
differential on a simulated device mesh.

The differential tests need >= 2 devices.  Tier-1 CI runs single-device
and skips them; the dedicated simulated-mesh pass sets
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` before pytest so
``jax.device_count()`` reports 8 and the full suite runs.
"""
import dataclasses

import jax
import numpy as np
import pytest

from conftest import (make_requests, pallas_modes, run_paged,
                      servable_smoke_configs, smoke_params)
from repro.configs import get_config
from repro.core import latency as lat
from repro.launch.mesh import sim_mesh
from repro.launch.placement import Placement, Topology, placements_summary
from repro.obs import trace as tr_mod
from repro.obs.check_trace import check
from repro.serving import fleet as fleet_mod
from repro.serving.continuous import ContinuousBatcher, LatencyProfile
from repro.serving.fleet import FleetRouter, pool_candidates
from repro.serving.traffic import SimRequest

needs_mesh = pytest.mark.skipif(
    jax.device_count() < 2,
    reason="needs a simulated multi-device mesh (set XLA_FLAGS="
           "--xla_force_host_platform_device_count=8 before jax imports)")


# -- the clock contract's transfer terms ------------------------------------

def test_xfer_zero_and_monotone():
    assert lat.xfer_s(0) == 0.0
    assert lat.xfer_s(-4) == 0.0
    a, b = lat.xfer_s(1 << 10), lat.xfer_s(1 << 20)
    assert 0.0 < a < b
    # latency floor: a single byte still pays the link latency
    assert lat.xfer_s(1, "ici") >= lat.ICI_LAT_S
    assert lat.xfer_s(1, "dcn") >= lat.DCN_LAT_S


def test_xfer_dcn_much_slower_than_ici():
    # latency-dominated regime: small payloads pay the 25x hop latency
    assert lat.xfer_s(64, "dcn") > 10.0 * lat.xfer_s(64, "ici")
    # bandwidth-dominated regime: still strictly slower
    assert lat.xfer_s(1 << 24, "dcn") > lat.xfer_s(1 << 24, "ici")
    with pytest.raises(ValueError):
        lat.xfer_s(64, "pcie")


def test_allreduce_zero_cases_and_scaling():
    assert lat.allreduce_s(1 << 20, 1) == 0.0      # no peers, no collective
    assert lat.allreduce_s(0, 8) == 0.0
    n = 1 << 20
    t2, t8 = lat.allreduce_s(n, 2), lat.allreduce_s(n, 8)
    # ring all-reduce: 2(n-1)/n * bytes/bw — grows with group size but
    # stays bounded by 2x the wire time
    assert 0.0 < t2 < t8
    assert t8 < 2.0 * n / lat.Hardware().ici_bw + 16 * lat.ICI_LAT_S


def test_tp_collective_prices_per_layer_allreduces():
    cfg = get_config("dbrx-132b")
    assert lat.tp_collective_s(cfg, 1, 1) == 0.0
    assert lat.tp_collective_s(cfg, 0, 8) == 0.0
    one = lat.tp_collective_s(cfg, 1, 8)
    assert one == pytest.approx(
        2.0 * cfg.n_layers * lat.allreduce_s(cfg.d_model * 2.0, 8))
    # the mispricing lever: the same group over DCN is orders slower
    assert lat.tp_collective_s(cfg, 1, 8, link="dcn") > 10.0 * one


# -- tensor-parallel profile pricing ----------------------------------------

def test_profile_tp_splits_compute_and_taxes_collectives():
    cfg = get_config("dbrx-132b")
    base = LatencyProfile(cfg, 16.0)
    tp8 = LatencyProfile(cfg, 16.0, tp=8, tp_link="ici")
    free = LatencyProfile(cfg, 16.0, tp=8, tp_link=None)
    # collective-free tp split is strictly faster per step (8x the chips)
    assert free.step_s(1, 256) < base.step_s(1, 256)
    # the priced profile pays exactly the collective on top
    assert tp8.step_s(1, 256) == pytest.approx(
        free.step_s(1, 256) + lat.tp_collective_s(cfg, 1, 8, hw=tp8.hw))
    assert tp8.prefill_s(256) == pytest.approx(
        free.prefill_s(256) + lat.tp_collective_s(cfg, 256, 8, hw=tp8.hw))
    # service_s inherits both terms; a DCN-spanning group is far slower
    dcn = LatencyProfile(cfg, 16.0, tp=8, tp_link="dcn")
    assert dcn._collective_s(1) > 10.0 * tp8._collective_s(1)
    assert dcn.step_s(1, 256) > 3.0 * tp8.step_s(1, 256)


def test_net_blind_twin_drops_collectives_only():
    cfg = get_config("qwen2.5-7b")
    tp = LatencyProfile(cfg, 16.0, tp=4, tp_link="dcn")
    blind = tp.net_blind()
    assert blind is tp.net_blind()           # memoized
    assert blind.hw is tp.hw                 # same compute split, no re-split
    assert blind._collective_s(1) == 0.0
    assert blind.step_s(1, 128) < tp.step_s(1, 128)
    # a tp=1 profile is its own blind twin
    flat = LatencyProfile(cfg, 16.0)
    assert flat.net_blind() is flat


# -- network physics on requests --------------------------------------------

def _req(rid, *, t=0.0, prompt=64, new=8, deadline=1.0):
    return SimRequest(rid=rid, cls_name="t", t_arrive=t, prompt_len=prompt,
                      max_new=new, deadline_s=deadline)


def test_deadline_abs_shrinks_by_response_hop():
    r = _req(0, deadline=1.0)
    assert r.deadline_abs == pytest.approx(1.0)
    r.net_out_s = 0.25
    assert r.deadline_abs == pytest.approx(0.75)
    # fresh() clears placement physics along with lifecycle state
    assert r.fresh().net_out_s == 0.0 and r.fresh().t_ready is None


def test_admission_waits_for_prompt_landing():
    prof = LatencyProfile(get_config("qwen2.5-1.5b"), 4.0)
    b = ContinuousBatcher(prof, slots=2, policy="serve")
    here = _req(0, deadline=10.0)
    remote = _req(1, deadline=10.0)
    remote.t_ready = 0.2                     # prompt lands after its hop
    for r in (here, remote):
        b.submit(r)
    b.run()
    assert here.t_admit < 0.2
    assert remote.t_admit >= 0.2
    assert not remote.dropped


def test_topology_dispatch_and_placement():
    topo = Topology(n_hosts=2, chips_per_host=8)
    assert topo.dispatch(Placement(host=0), 64, 8) == (0.0, 0.0, "local")
    in_s, out_s, link = topo.dispatch(Placement(host=1), 64, 8)
    assert link == "dcn" and in_s > 0.0 and out_s > 0.0
    assert in_s > out_s                      # 64 prompt tokens vs 8 out
    assert topo.place_tp(8).link == "ici"
    assert topo.place_tp(16).link == "dcn"   # spans hosts
    hosts = [p.host for p in topo.spread(4, tp=4)]
    assert hosts == [0, 0, 1, 1]             # 2 tp-4 engines per 8-chip host
    assert "2 hosts" in placements_summary(topo.spread(2), topo)


# -- net-aware vs net-blind routing -----------------------------------------

def _two_engine_fleet(net_aware, topo, placements, *, slots=1):
    """Two identical operating points; only their placement differs —
    engine 0 co-located with the ingress, engine 1 across DCN."""
    cfg = get_config("qwen2.5-7b")
    eps = fleet_mod._synthetic_eps(cfg)
    cands = pool_candidates([("qwen2.5-7b", cfg, eps, 0.0)] * 2)
    return FleetRouter(cands, quality=lambda c: 1.0, slots=slots,
                       policy="serve", placements=placements, topo=topo,
                       net_aware=net_aware, tracer=tr_mod.Tracer())


def test_router_prices_dispatch_hops_when_aware():
    """With a (deliberately) slow DCN, the aware router eats queue wait on
    the co-located engine rather than pay the hop; the blind router
    load-balances onto the remote engine — and pays the hop anyway,
    because physics is applied to every dispatch, priced or not."""
    slow = dataclasses.replace(lat.V5E, dcn_lat_s=2.0)
    topo = Topology(n_hosts=2, chips_per_host=8, hw=slow)
    placements = [Placement(host=0), Placement(host=1)]
    reqs = [_req(i, t=0.05 * i, prompt=256, new=8, deadline=100.0)
            for i in range(4)]

    aware = _two_engine_fleet(True, topo, placements)
    aware.run([r.fresh() for r in reqs])
    blind = _two_engine_fleet(False, topo, placements)
    blind.run([r.fresh() for r in reqs])

    assert all(r.engine_idx == 0 for r in aware.retired)
    assert any(r.engine_idx == 1 for r in blind.retired)
    # physics bites the blind remote request: the prompt lands a hop
    # late (admission gated on t_ready) and the response hop lands in
    # the client-facing latency
    remote = [r for r in blind.retired if r.engine_idx == 1][0]
    assert remote.net_in_s >= 2.0 and remote.net_out_s >= 2.0
    assert remote.t_admit >= remote.t_arrive + remote.net_in_s
    assert remote.latency_s >= remote.net_in_s + remote.net_out_s
    # the route.xfer vocabulary is emitted and the stream stays clean
    for fl, aware_flag in ((aware, True), (blind, False)):
        xf = [e for e in fl.tr.events
              if e.name == tr_mod.ROUTE_XFER]
        assert len(xf) == len(reqs)
        assert all(e.args["aware"] is aware_flag for e in xf)
        assert check(fl.tr.events) == []
    links = {e.args["link"] for e in blind.tr.events
             if e.name == tr_mod.ROUTE_XFER}
    assert links == {"local", "dcn"}


def test_router_mispricing_costs_goodput_on_dcn_spanning_tp():
    """An engine whose tp group spans hosts (DCN collectives) is honestly
    slow.  The aware router steers around it; the blind router — seeing
    its collective-free twin — keeps using it and misses deadlines."""
    cfg = get_config("qwen2.5-7b")
    eps = fleet_mod._synthetic_eps(cfg)
    cands = pool_candidates([("qwen2.5-7b", cfg, eps, 0.0)] * 2)
    topo = Topology(n_hosts=2, chips_per_host=8)
    placements = [Placement(host=0, tp=4, link="ici"),
                  topo.place_tp(16)]          # spans hosts -> dcn
    assert placements[1].link == "dcn"

    fast = LatencyProfile(cfg, cands[0].avg_bits, tp=4, tp_link="ici")
    slow = LatencyProfile(cfg, cands[1].avg_bits, tp=16, tp_link="dcn")
    s_fast, s_slow = fast.service_s(256, 8), slow.service_s(256, 8)
    assert s_slow > 3.0 * s_fast          # the mispricing is material...
    # ...and blind pricing inverts the ordering: 16 chips with free
    # collectives look faster than 4
    assert slow.net_blind().service_s(256, 8) < s_fast

    deadline = 3.0 * s_fast
    reqs = [_req(i, t=s_fast * i, prompt=256, new=8, deadline=deadline)
            for i in range(10)]
    outs = {}
    for awarev in (True, False):
        fl = FleetRouter(cands, quality=lambda c: 1.0, slots=2,
                         policy="serve", placements=placements, topo=topo,
                         net_aware=awarev)
        fl.run([r.fresh() for r in reqs])
        outs[awarev] = fl.retired
    met = {k: sum(1 for r in v if r.met_deadline) for k, v in outs.items()}
    assert all(r.engine_idx == 0 for r in outs[True])
    assert any(r.engine_idx == 1 for r in outs[False])
    assert met[True] == len(reqs)
    assert met[True] > met[False]


# -- sharded vs unsharded differential (needs the simulated mesh) -----------

def _mesh_cases():
    names = [n for n, _ in servable_smoke_configs()
             if n in ("qwen-sim-1.5b", "dbrx-132b")]
    return [(n, p) for n in names for p in pallas_modes()]


@needs_mesh
@pytest.mark.parametrize("name,use_pallas", _mesh_cases())
def test_sharded_decode_token_identical(name, use_pallas):
    """A tp=2 head-sharded engine emits byte-identical tokens to its
    unsharded twin — GSPMD partitions the same jitted computation, it
    must not change it.  Covers a dense and a moe stack, both kernel
    modes."""
    cfg = dict(servable_smoke_configs())[name]
    params = smoke_params(name)
    mesh = sim_mesh(2)
    assert mesh is not None

    base = make_requests(cfg, [9, 17, 5], max_new=4)
    shard = make_requests(cfg, [9, 17, 5], max_new=4)
    run_paged(params, cfg, base, use_pallas=use_pallas)
    _, eng = run_paged(params, cfg, shard, use_pallas=use_pallas, mesh=mesh,
                       tracer=tr_mod.Tracer())

    assert eng.tp == 2
    assert eng.cache.tp == 2
    for a, b in zip(base, shard):
        assert a.result_tokens is not None
        assert np.array_equal(a.result_tokens, b.result_tokens), \
            f"{name} pallas={use_pallas}: sharded decode diverged"

    # the shard-step vocabulary is emitted with the engine's tp and a
    # non-negative collective price, and the checker (including the
    # per-shard page-conservation cross-check) accepts the stream
    ev = eng.tr.events
    steps = [e for e in ev if e.name == tr_mod.ENGINE_SHARD_STEP]
    assert steps, "sharded engine emitted no engine.shard_step spans"
    assert all(e.args["tp"] == 2 for e in steps)
    assert all(e.args["collective_s"] >= 0.0 for e in steps)
    assert check(ev) == []


@needs_mesh
def test_sharded_engine_profile_carries_collective_tax():
    name = "qwen-sim-1.5b"
    cfg = dict(servable_smoke_configs())[name]
    reqs = make_requests(cfg, [8], max_new=2)
    _, eng = run_paged(params=smoke_params(name), cfg=cfg, reqs=reqs,
                       mesh=sim_mesh(2))
    assert eng.tp == 2
    assert eng.profile.tp == 2
    assert eng.profile._collective_s(1) > 0.0


def test_checker_rejects_shard_tp_mismatch():
    """engine.shard_step claiming tp=4 over a pool configured tp=2 is a
    page-conservation violation (each shard must hold 1/tp of every
    page's kv heads)."""
    tr = tr_mod.Tracer(wall_clock=lambda: 0.0)
    tr.instant(tr_mod.POOL_CONFIG, 0.0, track="e0/pool",
               groups={"layers": 4}, page_size=8, slots=2, tp=2)
    tr.span(tr_mod.ENGINE_SHARD_STEP, 0.0, 0.1, track="e0/steps",
            n_active=1, tp=4, link="ici", collective_s=1e-4)
    assert any("tp" in f for f in check(tr.events))


def test_checker_rejects_bad_shard_step_and_xfer_args():
    tr = tr_mod.Tracer(wall_clock=lambda: 0.0)
    tr.span(tr_mod.ENGINE_SHARD_STEP, 0.0, 0.1, track="steps",
            n_active=1, tp=1, link="ici", collective_s=1e-4)
    tr.span(tr_mod.ENGINE_SHARD_STEP, 0.2, 0.3, track="steps",
            n_active=1, tp=2, link="ici", collective_s=-1.0)
    tr.instant(tr_mod.ROUTE_XFER, 0.4, track="router", rid=0, cls="t",
               engine_idx=0, link="carrier-pigeon", in_s=0.0, out_s=0.0,
               aware=True)
    tr.instant(tr_mod.ROUTE_XFER, 0.5, track="router", rid=1, cls="t",
               engine_idx=0, link="dcn", in_s=-0.1, out_s=0.0, aware=True)
    f = check(tr.events)
    assert any("tp" in x for x in f)
    assert any("collective" in x for x in f)
    assert any("link" in x for x in f)
    assert any("negative" in x for x in f)
