"""The jit'd sampling layer + fast-draft / slow-verify speculative decoding.

Four contracts locked here:

1. **Device-side sampling == the host reference.**  ``sampler.sample``
   with ``temp == 0`` is exactly argmax; with temperature/top-k it equals
   an independently written host-side reference using the same lane-key
   derivation, and the ``lax.top_k`` mask equals the historical
   sort-based mask.  Draws are keyed by (seed, rid, position) only —
   invariant to batch slot.
2. **Greedy speculative decode is token-identical to dense decode** on
   the paged path — for any draft depth, any draft quality (full-precision
   drafts that always accept, 4-bit drafts that frequently diverge), both
   paged-attention implementations, chunked and monolithic prefill.  The
   accept/reject sampler's unit contract (emitted tokens are the verifier
   argmaxes through the first divergence) is also pinned directly.
3. **Stochastic speculative decode preserves the verifier's
   distribution** (model-free statistical check of ``spec_accept``
   against the tempered softmax target), and traced runs satisfy the
   spec commit discipline ``check_trace`` replays.
4. **The analytic mirror and pricing are coherent**: the
   ``ContinuousBatcher`` spec mode lands ``spec_expected_tokens`` per
   round on average with deterministic integer emissions, rounds collapse
   to dense steps under deadline pressure, and ``core.latency`` prices
   speculation monotonically (deeper rounds cost more; higher acceptance
   raises expected emission).
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from conftest import (make_requests, pallas_modes, run_paged,
                      servable_smoke_configs, smoke_params)
from repro.core import latency as lat_mod
from repro.core.fpx import SpecPoint
from repro.obs import check_trace
from repro.obs.trace import (SPEC_ACCEPT, SPEC_DRAFT, SPEC_VERIFY, Tracer)
from repro.serving import sampler as sampler_mod
from repro.serving.sampler import SamplerPolicy

SERVABLE = servable_smoke_configs()
#: one uniform-dense and one local:global config for the engine sweeps
DENSE_NAME = "qwen-sim-1.5b"
HYBRID_NAME = "gemma3-4b"


# ---------------------------------------------------------------------------
# 1. the sampling layer: device == host
# ---------------------------------------------------------------------------

def _host_lane_key(seed, stream, rid, position):
    k = jax.random.fold_in(jax.random.PRNGKey(seed), stream)
    return jax.random.fold_in(jax.random.fold_in(k, np.uint32(rid)),
                              np.uint32(position))


def test_greedy_policy_is_exact_argmax():
    logits = jax.random.normal(jax.random.PRNGKey(0), (5, 1, 97))
    out = sampler_mod.sample(sampler_mod.GREEDY, logits,
                             jnp.arange(5, dtype=jnp.int32),
                             jnp.zeros(5, jnp.int32))
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(logits.argmax(-1)))


def test_top_k_mask_matches_sort_reference():
    lg = jax.random.normal(jax.random.PRNGKey(1), (4, 1, 64))
    for top_k in (1, 5, 63):
        fast = np.asarray(sampler_mod._mask_top_k(lg, top_k))
        # the historical O(V log V) formulation: full sort, threshold at
        # the k-th largest
        kth = np.sort(np.asarray(lg), axis=-1)[..., -top_k][..., None]
        ref = np.where(np.asarray(lg) < kth, -1e30, np.asarray(lg))
        np.testing.assert_array_equal(fast, ref)


def test_sample_matches_host_reference_per_lane():
    pol = SamplerPolicy(temp=0.7, top_k=8, seed=3)
    logits = jax.random.normal(jax.random.PRNGKey(2), (4, 1, 50))
    rids = jnp.asarray([9, 0, 7, 9], jnp.int32)
    pos = jnp.asarray([0, 4, 1, 3], jnp.int32)
    out = np.asarray(sampler_mod.sample(pol, logits, rids, pos))
    for b in range(4):
        lg = np.asarray(logits)[b, 0] / pol.temp
        kth = np.sort(lg)[-pol.top_k]
        lg = np.where(lg < kth, -1e30, lg)
        key = _host_lane_key(pol.seed, sampler_mod.STREAM_POLICY,
                             int(rids[b]), int(pos[b]))
        ref = int(jax.random.categorical(key, jnp.asarray(lg)))
        assert out[b, 0] == ref, b


def test_draws_invariant_to_batch_slot():
    """The same (rid, position) draws the same token from the same row of
    logits no matter where in the batch the lane sits."""
    pol = SamplerPolicy(temp=1.0, seed=5)
    logits = jax.random.normal(jax.random.PRNGKey(4), (2, 1, 40))
    rids = jnp.asarray([11, 22], jnp.int32)
    pos = jnp.asarray([2, 6], jnp.int32)
    fwd = np.asarray(sampler_mod.sample(pol, logits, rids, pos))
    rev = np.asarray(sampler_mod.sample(pol, logits[::-1], rids[::-1],
                                        pos[::-1]))
    np.testing.assert_array_equal(fwd, rev[::-1])


def test_wave_generate_draws_independent_of_batch_packing():
    """ServingEngine.generate under temperature: a request's sampled
    tokens depend on (seed, rid, position) only — swapping batch rows
    (with their rids) swaps the outputs verbatim."""
    from repro.serving.engine import ServingEngine

    name, cfg = SERVABLE[0]
    eng = ServingEngine(smoke_params(name), cfg, max_ctx=64)
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (2, 6)).astype(np.int32))
    rids = jnp.asarray([4, 9], jnp.int32)
    fwd = np.asarray(eng.generate({"tokens": toks}, max_new=4, temp=0.8,
                                  rids=rids).new_tokens)
    rev = np.asarray(eng.generate({"tokens": toks[::-1]}, max_new=4,
                                  temp=0.8, rids=rids[::-1]).new_tokens)
    np.testing.assert_array_equal(fwd, rev[::-1])


# ---------------------------------------------------------------------------
# 2. spec_accept: greedy token identity + unit semantics
# ---------------------------------------------------------------------------

def _one_hot_logits(tokens, vocab):
    """(B, C) target tokens -> (B, C, V) logits whose argmax is exactly
    those tokens."""
    return jax.nn.one_hot(jnp.asarray(tokens), vocab) * 10.0


@pytest.mark.parametrize("draft,verify,emitted", [
    # full accept: every draft matches the verifier, bonus token rides
    ([3, 5, 7], [3, 5, 7, 9], [3, 5, 7, 9]),
    # first divergence at position 1: keep d1, emit the verifier's fix
    ([3, 6, 7], [3, 5, 7, 9], [3, 5]),
    # immediate divergence: the round still emits the verifier's token
    ([4, 5, 7], [3, 5, 7, 9], [3]),
    # late divergence
    ([3, 5, 8], [3, 5, 7, 9], [3, 5, 7]),
])
def test_spec_accept_greedy_emits_verifier_prefix(draft, verify, emitted):
    V = 16
    toks, n = sampler_mod.spec_accept(
        sampler_mod.GREEDY, jnp.asarray([draft], jnp.int32),
        _one_hot_logits([draft], V), _one_hot_logits([verify], V),
        jnp.asarray([0], jnp.int32), jnp.asarray([0], jnp.int32))
    n = int(n[0])
    assert n == len(emitted)
    assert np.asarray(toks)[0, :n].tolist() == emitted


def test_spec_accept_stochastic_preserves_verifier_distribution():
    """Model-free: for arbitrary fixed draft/verify logits, the first
    token a speculative round emits must be distributed as the verifier's
    tempered softmax — the defining property of accept/reject + residual
    resampling.  Many (rid) replicas of the same round give the empirical
    law; compare in total variation."""
    V, k, B = 12, 3, 4000
    pol = SamplerPolicy(temp=1.0, seed=11)
    rng = np.random.default_rng(7)
    d_logits = jnp.asarray(np.repeat(rng.normal(size=(1, k, V)), B, axis=0),
                           jnp.float32)
    v_logits = jnp.asarray(np.repeat(rng.normal(size=(1, k + 1, V)), B,
                                     axis=0), jnp.float32)
    rids = jnp.arange(B, dtype=jnp.int32)
    pos0 = jnp.zeros((B,), jnp.int32)
    # drafts must themselves be drawn from the draft distribution — the
    # accept identity only holds for proposals sampled from p_d
    drafts = []
    for j in range(k):
        drafts.append(sampler_mod.sample(
            pol, d_logits[:, j:j + 1], rids, pos0 + j,
            stream=sampler_mod.STREAM_DRAFT))
    draft_toks = jnp.concatenate(drafts, axis=1)
    toks, n_emit = sampler_mod.spec_accept(pol, draft_toks, d_logits,
                                           v_logits, rids, pos0)
    first = np.asarray(toks)[:, 0]
    emp = np.bincount(first, minlength=V) / B
    target = np.asarray(sampler_mod.policy_probs(pol, v_logits[0, 0]))
    tv = 0.5 * np.abs(emp - target).sum()
    assert tv < 0.05, tv
    assert np.all(np.asarray(n_emit) >= 1)
    assert np.all(np.asarray(n_emit) <= k + 1)


# ---------------------------------------------------------------------------
# 3. the paged engine: spec == dense (greedy), traced discipline
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("use_pallas", pallas_modes())
@pytest.mark.parametrize("name", [DENSE_NAME, HYBRID_NAME])
@pytest.mark.parametrize("k,draft_bits,chunk", [
    (1, 16.0, None),    # shallow, always-accept drafts
    (2, 4.0, None),     # low-bit drafts: frequent argmax divergence
    (3, 4.0, 8),        # deep + rejection-heavy + chunked prefill
])
def test_spec_decode_token_identical_to_dense(name, k, draft_bits, chunk,
                                              use_pallas):
    cfg = dict(SERVABLE)[name]
    params = smoke_params(name)
    lens, max_new = (9, 14, 5), 6
    reqs_d = make_requests(cfg, lens, max_new=max_new)
    run_paged(params, cfg, reqs_d, chunk=chunk, use_pallas=use_pallas)
    reqs_s = make_requests(cfg, lens, max_new=max_new)
    run_paged(params, cfg, reqs_s, chunk=chunk, use_pallas=use_pallas,
              speculate=SpecPoint(k=k, draft_bits=draft_bits))
    for rd, rs in zip(reqs_d, reqs_s):
        np.testing.assert_array_equal(rd.result_tokens, rs.result_tokens)


def test_spec_decode_stochastic_deterministic_and_traced():
    """Temperature spec decode: reproducible under a fixed sampler seed,
    emits only in-vocab tokens, and its trace satisfies the spec commit
    discipline (accepted <= drafted, exactly-once, nothing dangling)."""
    name, cfg = DENSE_NAME, dict(SERVABLE)[DENSE_NAME]
    params = smoke_params(name)
    runs = []
    for _ in range(2):
        tracer = Tracer()
        reqs = make_requests(cfg, (7, 12), max_new=6)
        run_paged(params, cfg, reqs, speculate=SpecPoint(k=2),
                  sampler=SamplerPolicy(temp=0.9, top_k=20, seed=13),
                  tracer=tracer)
        runs.append([r.result_tokens.tolist() for r in reqs])
        assert check_trace.check(tracer.events) == []
        names = [e.name for e in tracer.events]
        assert SPEC_DRAFT in names and SPEC_VERIFY in names \
            and SPEC_ACCEPT in names
        for tok in runs[-1]:
            assert all(0 <= t < cfg.vocab for t in tok)
    assert runs[0] == runs[1]


def test_spec_trace_commit_violations_are_caught():
    """The replay actually bites: over-commit and dangling rounds fail."""
    tr = Tracer()
    tr.instant(SPEC_DRAFT, 0.0, track="steps", k=2, lanes=[0], drafted=2)
    tr.instant(SPEC_ACCEPT, 0.1, track="steps", lanes=[0], accepted=3,
               emitted=4)
    assert any("committed 3" in e for e in check_trace.check(tr.events))
    tr2 = Tracer()
    tr2.instant(SPEC_DRAFT, 0.0, track="steps", k=2, lanes=[0], drafted=2)
    assert any("dangling" in e for e in check_trace.check(tr2.events))
    tr3 = Tracer()
    tr3.instant(SPEC_ACCEPT, 0.0, track="steps", lanes=[0], accepted=0,
                emitted=1)
    assert any("without a pending" in e for e in check_trace.check(tr3.events))


def test_spec_admission_reserves_draft_headroom():
    """With speculation on, admission must keep k positions of block-table
    headroom: a request sized to the exact max_ctx boundary is trimmed
    below the dense-path budget instead of overflowing mid-round."""
    name, cfg = DENSE_NAME, dict(SERVABLE)[DENSE_NAME]
    params = smoke_params(name)
    max_ctx, k, S = 32, 3, 20
    cap_dense = max_ctx - S + 1
    reqs = make_requests(cfg, (S,), max_new=cap_dense)
    run_paged(params, cfg, reqs, max_ctx=max_ctx,
              speculate=SpecPoint(k=k, draft_bits=16.0))
    assert len(reqs[0].result_tokens) == cap_dense - k


# ---------------------------------------------------------------------------
# 4. the analytic mirror + pricing
# ---------------------------------------------------------------------------

def test_spec_expected_tokens_geometric():
    assert lat_mod.spec_expected_tokens(0, 0.8) == 1.0
    assert lat_mod.spec_expected_tokens(2, 0.0) == 1.0
    np.testing.assert_allclose(lat_mod.spec_expected_tokens(3, 1.0), 4.0)
    np.testing.assert_allclose(lat_mod.spec_expected_tokens(2, 0.5), 1.75)


def test_speculate_pricing_monotonic():
    from repro.configs import get_config
    cfg = get_config("qwen2.5-7b")
    rounds = [lat_mod.speculate_round_s(cfg, k=k, context=256)
              for k in (1, 2, 4)]
    assert rounds[0] < rounds[1] < rounds[2]
    # higher acceptance -> cheaper effective per-token time at equal k
    fast = lat_mod.speculate_s(cfg, k=4, accept=0.9, context=256)
    slow = lat_mod.speculate_s(cfg, k=4, accept=0.3, context=256)
    assert fast < slow
    # cross-model drafting with a small config undercuts self-drafting
    # at full precision
    small = get_config("qwen2.5-1.5b")
    cross = lat_mod.speculate_round_s(cfg, k=4, context=256,
                                      draft_cfg=small, draft_bits=16)
    self_full = lat_mod.speculate_round_s(cfg, k=4, context=256,
                                          draft_bits=16)
    assert cross < self_full


def test_profile_tok_s_amortizes_round():
    from repro.configs import get_config
    from repro.serving.continuous import LatencyProfile
    cfg = get_config("qwen2.5-7b")
    spec = SpecPoint(k=4, accept=0.9, draft_bits=4.0)
    dense = LatencyProfile(cfg, 16.0)
    sp = LatencyProfile(cfg, 16.0, spec=spec)
    assert sp.tok_s(1, 256) == pytest.approx(
        sp.spec_round_s(1, 256) / spec.expected_tokens())
    # at 90% acceptance with 4-bit drafts, speculation must beat dense
    # per-token — this is the break-even the router exploits
    assert sp.tok_s(1, 256) < dense.tok_s(1, 256)
    assert dense.tok_s(1, 256) == dense.step_s(1, 256)


def _sim_reqs(n, *, deadline, max_new=16, prompt=32, spacing=1000.0):
    from repro.serving.traffic import SimRequest
    return [SimRequest(rid=i, cls_name="t", t_arrive=i * spacing,
                       prompt_len=prompt, max_new=max_new,
                       deadline_s=deadline) for i in range(n)]


def test_batcher_spec_mode_deterministic_and_exact():
    """The analytic spec rounds emit every budgeted token, deterministically,
    and finish sooner than the dense batcher when acceptance is high."""
    from repro.configs import get_config
    from repro.serving.continuous import ContinuousBatcher, LatencyProfile
    cfg = get_config("qwen2.5-7b")
    spec = SpecPoint(k=4, accept=0.9, draft_bits=4.0)

    def run(profile):
        b = ContinuousBatcher(profile, slots=2, policy="serve")
        reqs = _sim_reqs(3, deadline=100.0)
        for r in reqs:
            b.submit(r)
        b.drain()
        return reqs, b.t

    r1, t_spec = run(LatencyProfile(cfg, 16.0, spec=spec))
    r2, t_spec2 = run(LatencyProfile(cfg, 16.0, spec=spec))
    assert t_spec == t_spec2
    assert [r.tokens_done for r in r1] == [r.tokens_done for r in r2]
    assert all(r.tokens_done == r.max_new for r in r1)
    _, t_dense = run(LatencyProfile(cfg, 16.0))
    assert t_spec < t_dense


def test_batcher_collapses_to_dense_under_deadline_pressure():
    """A deadline tighter than one spec round forces dense steps: the
    traced run contains no spec rounds at all, and the request still
    lands every token the admission projection granted."""
    from repro.configs import get_config
    from repro.serving.continuous import ContinuousBatcher, LatencyProfile
    cfg = get_config("qwen2.5-7b")
    spec = SpecPoint(k=4, accept=0.9, draft_bits=4.0)
    profile = LatencyProfile(cfg, 16.0, spec=spec)
    round_s = profile.spec_round_s(1, 32)
    tr = Tracer()
    b = ContinuousBatcher(profile, slots=1, policy="serve", tracer=tr)
    # deadline covers prefill + a few dense steps but not one full round
    tight = profile.prefill_s(32) + round_s * 0.5
    reqs = _sim_reqs(1, deadline=tight, max_new=4)
    for r in reqs:
        b.submit(r)
    b.drain()
    assert reqs[0].tokens_done == 4
    assert not any(e.name == SPEC_DRAFT for e in tr.events)
    assert check_trace.check(tr.events) == []


def test_fleet_learns_per_class_draft_depth():
    """The spec-widened pool: chat-like slack-rich traffic must settle on
    a speculative operating point — at equal verifier quality the bandit's
    load-aware draw routes work to the arm whose rounds drain faster, so
    the chat workhorse (most-pulled arm) is a draft-depth variant, not the
    dense point — the per-class draft-depth learning the grid exists for."""
    from repro.serving.fleet import (FleetRouter, demo_quality,
                                     demo_spec_pool)
    from repro.serving.traffic import chat_class, generate
    pool = demo_spec_pool()
    assert any(c.spec is not None for c in pool)
    router = FleetRouter(pool, quality=demo_quality, slots=4,
                         policy="degrade", mode="bandit", epsilon=0.2,
                         seed=0)
    arrivals = generate([chat_class(rate_hz=20.0)], horizon_s=20.0, seed=3)
    router.run(arrivals)
    sel = router.selectors["chat"]
    workhorse = sel.grid[max(range(len(sel.grid)),
                             key=lambda i: sel.counts[i])]
    assert workhorse.spec is not None
    # and speculation carried the majority of the class's traffic
    spec_pulls = sum(n for n, c in zip(sel.counts, sel.grid)
                     if c.spec is not None)
    assert spec_pulls > sum(sel.counts) / 2
