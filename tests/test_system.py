"""End-to-end behaviour tests for the paper's system.

The full FPX causal chain on a real (sim-scale) model: train -> calibrate
(Algorithm 1) -> assign (Eq. 7) -> quantized serving -> latency/quality
trade-off present; plus the latency-sensitive reward coupling on HFTBench.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.bench import agents as ag
from repro.bench.env import Teacher
from repro.bench.hft import HFTBench, run_session
from repro.configs import get_config
from repro.core import assign as A, calibrate as C, latency as L
from repro.models import transformer as T
from repro.models.modules import ExecContext


@pytest.fixture(scope="module")
def trained():
    """A small decision model trained enough to be clearly above chance."""
    teacher = Teacher(n_features=8, n_values=6, n_classes=3, seed=3,
                      hidden=48, temperature=0.5)
    cfg = get_config("qwen-sim-3b")
    params, acc = ag.train_decision_model(cfg, teacher, steps=200, batch=32,
                                          prompt_len=16, seed=0)
    return cfg, params, teacher


def test_training_beats_chance(trained):
    cfg, params, teacher = trained
    acc = ag.eval_decision_accuracy(params, cfg, teacher, prompt_len=16,
                                    n=256)
    assert acc > 0.45            # 3-way chance = 0.33


def test_fpx_end_to_end(trained):
    """Calibrate -> assign -> the full gamma sweep is well-behaved:
    fp8 ~ fp16; latency strictly improves with gamma; FP4 never helps."""
    cfg, params, teacher = trained
    rng = np.random.default_rng(0)
    batches = [ag.decision_batch(teacher, rng, batch=4, prompt_len=16)
               for _ in range(2)]
    eps = C.calibrate(params, cfg, batches)
    assert len(eps) == cfg.n_layers * 7

    acc16 = ag.eval_decision_accuracy(params, cfg, teacher, prompt_len=16,
                                      n=256)
    accs, lats = [], []
    full = get_config("qwen2.5-3b")
    for g in (0.0, 0.5, 1.0):
        asn = A.assign_precision(eps, g)
        ctx = ExecContext(policy=asn, default_bits=8)
        accs.append(ag.eval_decision_accuracy(params, cfg, teacher, ctx=ctx,
                                              prompt_len=16, n=256))
        lats.append(L.decision_latency(full, w_bits=A.avg_bits(asn)))
    assert abs(accs[0] - acc16) < 0.08          # FP8 near-lossless
    assert lats[0] > lats[1] > lats[2]          # gamma buys latency
    assert accs[2] <= accs[0] + 0.04            # FP4 never *helps*


def test_latency_reward_coupling(trained):
    """Same decisions, different speed: reward must respond to latency
    (paper Eq. 5)."""
    cfg, params, teacher = trained
    env = HFTBench()

    def make_agent(latency_s):
        spec = ag.AgentSpec(name="x", sim_cfg=cfg, params=params,
                            full_cfg=get_config("qwen2.5-3b"))
        return ag.LLMAgent(spec, n_actions=3, latency_override_s=latency_s)

    y_fast = run_session(env, make_agent(0.1), seed=0)["daily_yield"]
    y_slow = run_session(env, make_agent(2.5), seed=0)["daily_yield"]
    assert y_fast > y_slow       # same decisions, faster fills


def test_sharded_forward_matches_unsharded():
    """The production sharding rules don't change numerics (1-device mesh)."""
    from repro.launch import shardings as sh
    from repro.launch.mesh import make_host_mesh
    cfg = get_config("qwen-sim-1.5b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
    want = T.forward(params, cfg, {"tokens": toks})
    mesh = make_host_mesh()
    with mesh:
        p_sh = sh.param_shardings(params, mesh)
        fn = jax.jit(lambda p, t: T.forward(p, cfg, {"tokens": t}),
                     in_shardings=(p_sh, sh.token_sharding(mesh, 2)))
        got = fn(params, toks)
    np.testing.assert_allclose(np.asarray(want), np.asarray(got),
                               rtol=1e-4, atol=1e-4)
