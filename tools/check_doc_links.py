#!/usr/bin/env python
"""Verify that file pointers in the doc set still point at real files.

The architecture and benchmark docs cite source files constantly
(``src/repro/serving/kv_cache.py``, ``benchmarks/table_sessions.py``,
...), and nothing else keeps those pointers honest when a module moves.
This checker extracts every repo-relative path mentioned in the docs —
backtick-quoted paths and relative markdown link targets — and fails if
any no longer exists.

    python tools/check_doc_links.py [files...]

With no arguments it scans ``docs/*.md``, ``README.md``, and
``ROADMAP.md``.  Exit 0 = every pointer resolves; exit 1 prints one line
per dangling pointer.
"""
from __future__ import annotations

import glob
import os
import re
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

#: backtick-quoted repo paths: at least one '/' (a bare module name in
#: prose is not a checkable pointer), a known source suffix.  ``:line``
#: suffixes are tolerated.
BACKTICK = re.compile(
    r"`([A-Za-z0-9_.-]+(?:/[A-Za-z0-9_.-]+)+\.(?:py|md|csv|json|yml|toml|txt))"
    r"(?::\d+)?`")
#: markdown links with a relative target (skip http/https/mailto/anchors)
MDLINK = re.compile(r"\[[^\]]*\]\((?!https?:|mailto:|#)([^)#\s]+)")

#: roots a pointer may be relative to: the repo, the package source tree
#: (``kernels/paged_attention.py``-style pointers in prose), and the
#: package itself (``serving/sampler.py``, ``launch/mesh.py``).
ROOTS = ("", "src", os.path.join("src", "repro"))


def pointers(text: str):
    for m in BACKTICK.finditer(text):
        yield m.group(1)
    for m in MDLINK.finditer(text):
        yield m.group(1)


def check_file(path: str):
    """Yield (pointer, resolved) for each dangling pointer in *path*."""
    with open(path) as f:
        text = f.read()
    base = os.path.dirname(os.path.abspath(path))
    for ptr in pointers(text):
        # glob-style pointers (results/fig1*.csv) resolve if any match
        roots = [os.path.join(REPO, r) for r in ROOTS] + [base]
        for root in roots:
            target = os.path.normpath(os.path.join(root, ptr))
            if os.path.exists(target) or glob.glob(target):
                break
        else:
            yield ptr, os.path.relpath(path, REPO)


def main(argv=None) -> int:
    args = (argv if argv is not None else sys.argv[1:])
    files = args or (sorted(glob.glob(os.path.join(REPO, "docs", "*.md")))
                     + [os.path.join(REPO, "README.md"),
                        os.path.join(REPO, "ROADMAP.md")])
    dangling = []
    for path in files:
        if not os.path.exists(path):
            continue
        dangling.extend(check_file(path))
    for ptr, src in dangling:
        print(f"{src}: dangling file pointer `{ptr}`", file=sys.stderr)
    if not dangling:
        print(f"doc links OK ({len(files)} files scanned)")
    return 1 if dangling else 0


if __name__ == "__main__":
    raise SystemExit(main())
